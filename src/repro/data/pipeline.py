"""Token data pipeline: deterministic, per-host sharded, resumable.

Production constraints this implements (DESIGN.md §6):

- **Per-host sharding**: each host reads only its slice of the global batch
  (``host_id / n_hosts``); the arrays produced are the *local* shard, to be
  assembled with ``jax.make_array_from_process_local_data`` on real multi-
  host topologies (single-process here: local == global).
- **Exactly-once accounting**: the pipeline state is a (epoch, step,
  rng-counter) triple, checkpointed alongside the model so restarts resume
  mid-epoch without repeating or skipping samples.
- **Deterministic & host-count invariant**: sample content is a pure
  function of (seed, epoch, step) at *global-batch* granularity — each
  host materialises the global batch's token draw and slices its share,
  so an elastic re-mesh that changes the host count (straggler eviction,
  pool join) resumes the identical global sample stream.  ``reshard``
  re-slices a live pipeline onto a new (host_id, n_hosts) without
  touching its position.

Sources: synthetic LM tokens (zipf-ish unigram draw — keeps the loss
non-degenerate), a memory-mapped binary token file, or a text corpus via a
byte-level codec (examples use the synthetic source).
"""
from __future__ import annotations

import dataclasses
import os
from typing import Iterator

import jax
import numpy as np


@dataclasses.dataclass
class PipelineState:
    epoch: int = 0
    step: int = 0          # steps consumed within the epoch
    seed: int = 0

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "PipelineState":
        return cls(**{k: int(v) for k, v in d.items()})


@dataclasses.dataclass(frozen=True)
class DataCfg:
    global_batch: int
    seq_len: int
    vocab: int
    seed: int = 0
    source: str = "synthetic"        # "synthetic" | "tokens_file"
    path: str | None = None
    steps_per_epoch: int = 1 << 30   # synthetic = unbounded epochs


class TokenPipeline:
    """Iterator of {'tokens': (local_batch, seq+?) int32} batches."""

    def __init__(self, cfg: DataCfg, *, host_id: int | None = None,
                 n_hosts: int | None = None,
                 state: PipelineState | None = None):
        self.cfg = cfg
        self.host_id = jax.process_index() if host_id is None else host_id
        self.n_hosts = jax.process_count() if n_hosts is None else n_hosts
        if cfg.global_batch % self.n_hosts:
            raise ValueError("global_batch must divide over hosts")
        self.local_batch = cfg.global_batch // self.n_hosts
        self.state = state or PipelineState(seed=cfg.seed)
        self._mmap = None
        if cfg.source == "tokens_file":
            if not cfg.path or not os.path.exists(cfg.path):
                raise FileNotFoundError(cfg.path)
            self._mmap = np.memmap(cfg.path, dtype=np.int32, mode="r")

    # --- deterministic content ---
    def _synthetic(self, epoch: int, step: int) -> np.ndarray:
        # content is seeded per GLOBAL batch row, so the stream survives an
        # elastic host-count change byte-identically (seeding per
        # (step, host) would re-deal every sample on re-mesh) while each
        # host only draws its own O(local_batch) rows
        B, S, V = self.local_batch, self.cfg.seq_len, self.cfg.vocab
        lo = self.host_id * B
        u = np.stack([
            np.random.default_rng(
                (self.state.seed, epoch, step, row)).random(S)
            for row in range(lo, lo + B)])
        # zipf-ish unigram over the vocab: learnable structure, finite loss
        return np.minimum((V ** u - 1.0), V - 1).astype(np.int32)

    def _from_file(self, epoch: int, step: int) -> np.ndarray:
        B, S = self.local_batch, self.cfg.seq_len
        n_tokens = self._mmap.shape[0]
        n_seqs = n_tokens // S
        rng = np.random.default_rng(self.state.seed + epoch)
        order = rng.permutation(n_seqs)
        base = (step * self.cfg.global_batch + self.host_id * B) % n_seqs
        idx = order[(base + np.arange(B)) % n_seqs]
        return np.stack([self._mmap[i * S:(i + 1) * S] for i in idx]) \
            .astype(np.int32)

    # --- iteration ---
    def next_batch(self) -> dict:
        st = self.state
        if self.cfg.source == "synthetic":
            toks = self._synthetic(st.epoch, st.step)
        else:
            toks = self._from_file(st.epoch, st.step)
        st.step += 1
        if st.step >= self.cfg.steps_per_epoch:
            st.epoch, st.step = st.epoch + 1, 0
        return {"tokens": toks}

    def __iter__(self) -> Iterator[dict]:
        while True:
            yield self.next_batch()

    # --- elastic re-sharding ---
    def reshard(self, *, host_id: int, n_hosts: int) -> "TokenPipeline":
        """The same stream re-sliced for a new host layout (same position).

        After straggler eviction the surviving hosts re-divide the
        *unchanged* global batch; because content is drawn at global
        granularity, the concatenation of all hosts' shards is identical
        before and after — exactly-once holds across the re-mesh.
        """
        return TokenPipeline(self.cfg, host_id=host_id, n_hosts=n_hosts,
                             state=PipelineState(**self.state.to_dict()))

    # --- checkpoint integration ---
    def state_dict(self) -> dict:
        return self.state.to_dict()

    def load_state_dict(self, d: dict) -> None:
        self.state = PipelineState.from_dict(d)


class MultimodalPipeline(TokenPipeline):
    """TokenPipeline plus a synthetic modality stream (M6 workloads).

    The vision/audio frontends are STUBS (see
    :mod:`repro.models.frontends`): real towers would emit precomputed
    embeddings, so the pipeline synthesises them — unit-normal
    ``patch_embeds`` (B, frontend_len, d_model) for ``vlm`` or ``frames``
    (B, src_len, d_model) for ``encdec`` — with the same per-global-row
    seeding discipline as the token draw, so the stream stays
    deterministic, resumable, and host-count invariant under
    :meth:`reshard`.
    """

    def __init__(self, cfg: DataCfg, *, modality: str, d_model: int,
                 frontend_len: int = 0, src_len: int = 0,
                 host_id: int | None = None, n_hosts: int | None = None,
                 state: PipelineState | None = None):
        if modality not in ("vlm", "encdec"):
            raise ValueError(f"modality must be 'vlm' or 'encdec', "
                             f"got {modality!r}")
        if modality == "vlm" and frontend_len <= 0:
            raise ValueError("vlm needs frontend_len > 0 patch positions")
        if modality == "encdec" and src_len <= 0:
            raise ValueError("encdec needs src_len > 0 source frames")
        super().__init__(cfg, host_id=host_id, n_hosts=n_hosts, state=state)
        self.modality = modality
        self.d_model = d_model
        self.frontend_len = frontend_len
        self.src_len = src_len

    def _embeds(self, epoch: int, step: int, length: int) -> np.ndarray:
        # 7919 (the 1000th prime) offsets the stream id so modality rows
        # never collide with the token rows' (seed, epoch, step, row) keys
        B = self.local_batch
        lo = self.host_id * B
        return np.stack([
            np.random.default_rng((self.state.seed, epoch, step, 7919, row))
            .standard_normal((length, self.d_model))
            for row in range(lo, lo + B)]).astype(np.float32)

    def next_batch(self) -> dict:
        epoch, step = self.state.epoch, self.state.step
        batch = super().next_batch()          # advances the state
        if self.modality == "vlm":
            batch["patch_embeds"] = self._embeds(epoch, step,
                                                 self.frontend_len)
        else:
            batch["frames"] = self._embeds(epoch, step, self.src_len)
        return batch

    def reshard(self, *, host_id: int, n_hosts: int) -> "MultimodalPipeline":
        return MultimodalPipeline(
            self.cfg, modality=self.modality, d_model=self.d_model,
            frontend_len=self.frontend_len, src_len=self.src_len,
            host_id=host_id, n_hosts=n_hosts,
            state=PipelineState(**self.state.to_dict()))


def write_token_file(path: str, tokens: np.ndarray) -> None:
    np.asarray(tokens, np.int32).tofile(path)

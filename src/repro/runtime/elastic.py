"""Elastic re-meshing: restart the job at a different device count *or a
different hardware mix*.

Checkpoints are mesh-agnostic (full logical arrays + logical axis names), so
scaling in/out is: build the new mesh → rebuild the plan (ShardingRules give
the new PartitionSpecs; divisibility pruning silently drops shardings that
no longer divide) → ``CheckpointManager.restore`` with the new shardings.
The batch schedule is kept consistent by preserving *global* batch size —
dp changes only the per-device slice.

Two re-mesh flavours (DESIGN.md §2):

- :meth:`ElasticContext.remesh` — same hardware, different count (straggler
  eviction: a flagged host is excluded and the job resumes on N−k hosts).
- :meth:`ElasticContext.rebalance` — a *different hardware mix*: given the
  surviving cluster's per-device-group :class:`ClusterSpec` (e.g. the V100
  pod shrank and a T4 pool joined), the heterogeneity-aware search picks a
  fresh strategy, the balancer re-splits batch/layers in proportion to each
  group's effective FLOP/s, and the checkpoint restores into the new plan.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax

from repro.ckpt.checkpoint import CheckpointManager
from repro.core.planner import compile_plan
from repro.core.cost_model import ClusterSpec, StrategySpec, WorkloadMeta


def _ns(mesh, specs):
    import jax.sharding as shd
    return jax.tree.map(lambda s: shd.NamedSharding(mesh, s), specs,
                        is_leaf=lambda t: isinstance(t, shd.PartitionSpec))


@dataclasses.dataclass
class ElasticContext:
    """Rebuild (plan, params, opt_state) from a checkpoint on a new mesh."""
    model: Any
    optimizer: Any

    def remesh(self, ckpt: CheckpointManager, new_mesh,
               strategy: StrategySpec | None = None, *,
               cluster_spec: ClusterSpec | None = None,
               workload_meta: WorkloadMeta | None = None,
               placement=None, overlap: float = 0.0):
        """→ (step, plan, params, opt_state, extra) on ``new_mesh``.

        ``cluster_spec`` + ``workload_meta`` make the rebuilt plan carry a
        balanced heterogeneous placement (per-group batch shares) when the
        new hardware is mixed; a pre-computed ``placement`` (from the
        search) is attached as-is.  Raises FileNotFoundError when no
        committed checkpoint exists.
        """
        plan = compile_plan(self.model, new_mesh, strategy=strategy,
                            cluster_spec=cluster_spec,
                            workload_meta=workload_meta,
                            placement=placement, overlap=overlap)
        p_shapes = plan.param_shapes
        o_shapes = jax.eval_shape(self.optimizer.init, p_shapes)
        target = {"params": p_shapes, "opt": o_shapes}
        shardings = {
            "params": _ns(new_mesh, plan.param_specs),
            "opt": _ns(new_mesh, plan.opt_specs(self.optimizer)),
        }
        out = ckpt.restore_latest(target, shardings=shardings)
        if out is None:
            raise FileNotFoundError(
                f"no committed checkpoint in {ckpt.directory}")
        step, tree, extra = out
        return step, plan, tree["params"], tree["opt"], extra

    def rebalance(self, ckpt: CheckpointManager,
                  cluster_spec: ClusterSpec,
                  workload_meta: WorkloadMeta, *, new_mesh=None,
                  overlap: float = 0.5):
        """Re-mesh onto a **different hardware mix**.

        Runs the heterogeneity-aware strategy search over ``cluster_spec``
        (slowest-group-dominates cost, per-group HBM pruning), then
        restores the checkpoint into the winning plan — which carries the
        exact placement the search scored (not a re-balance at different
        assumptions).  The plan's ``placement.batch_slices()`` tells the
        data loader each group's new throughput-proportional share of the
        (unchanged) global batch.

        The winning strategy is only known after the search, so the mesh
        is normally built here (``new_mesh=None``).  A caller-supplied
        mesh is validated against the winner — a mesh realising a
        different (dp, tp, pp) would silently train a different
        parallelism than the placement describes.
        """
        from repro.core.auto import search
        from repro.core.planner import mesh_for_strategy
        cands = search(workload_meta, cluster_spec, top_k=1, overlap=overlap)
        if not cands:
            raise RuntimeError(
                f"no feasible strategy for {workload_meta.name} on "
                + "+".join(f"{g.n_devices}×{g.hw.name}"
                           for g in cluster_spec.groups))
        strat = cands[0].strategy
        if new_mesh is None:
            new_mesh = mesh_for_strategy(strat, cluster_spec=cluster_spec)
        else:
            dp = 1
            for a in ("pod", "data"):
                if a in new_mesh.shape:
                    dp *= new_mesh.shape[a]
            realized = (dp, new_mesh.shape.get("model", 1),
                        new_mesh.shape.get("stage", 1))
            if realized != (strat.dp, strat.tp, strat.pp):
                raise ValueError(
                    f"new_mesh realises dp×tp×pp={realized} but the "
                    f"search picked {strat.describe()} — build the mesh "
                    f"with mesh_for_strategy(strategy) or omit new_mesh")
        return self.remesh(ckpt, new_mesh, strategy=strat,
                           cluster_spec=cluster_spec,
                           workload_meta=workload_meta,
                           placement=cands[0].placement, overlap=overlap)


def shrink_devices(devices, exclude_hosts: set):
    """Filter a device list to exclude flagged hosts (straggler eviction)."""
    return [d for d in devices if d.process_index not in exclude_hosts]

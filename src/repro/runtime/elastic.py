"""Elastic re-meshing: restart the job at a different device count *or a
different hardware mix*.

Checkpoints are mesh-agnostic (full logical arrays + logical axis names), so
scaling in/out is: build the new mesh → rebuild the plan (ShardingRules give
the new PartitionSpecs; divisibility pruning silently drops shardings that
no longer divide) → ``CheckpointManager.restore`` with the new shardings.
The batch schedule is kept consistent by preserving *global* batch size —
dp changes only the per-device slice.

Two re-mesh flavours (DESIGN.md §2):

- :meth:`ElasticContext.remesh` — same hardware, different count (straggler
  eviction: a flagged host is excluded and the job resumes on N−k hosts).
- :meth:`ElasticContext.rebalance` — a *different hardware mix*: given the
  surviving cluster's per-device-group :class:`ClusterSpec` (e.g. the V100
  pod shrank and a T4 pool joined), the heterogeneity-aware search picks a
  fresh strategy, the balancer re-splits batch/layers in proportion to each
  group's effective FLOP/s, and the checkpoint restores into the new plan.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any

import jax

from repro.ckpt.checkpoint import CheckpointManager
from repro.core.planner import compile_plan
from repro.core.cost_model import ClusterSpec, StrategySpec, WorkloadMeta


def _ns(mesh, specs):
    import jax.sharding as shd
    return jax.tree.map(lambda s: shd.NamedSharding(mesh, s), specs,
                        is_leaf=lambda t: isinstance(t, shd.PartitionSpec))


def search_cluster(meta: WorkloadMeta, spec: ClusterSpec, *,
                   overlap: float = 0.5, search_kw: dict | None = None):
    """Best strategy candidate for ``spec``; raises when nothing fits.

    The single entry the elastic paths share (initial planning in the
    TrainController and :meth:`ElasticContext.rebalance`) — one place for
    the search defaults and the no-feasible-strategy error."""
    from repro.core.auto import search
    cands = search(meta, spec, top_k=1, overlap=overlap,
                   **(search_kw or {}))
    if not cands:
        raise RuntimeError(
            f"no feasible strategy for {meta.name} on "
            + "+".join(f"{g.n_devices}×{g.hw.name}" for g in spec.groups))
    return cands[0]


def plan_for_cluster(model, meta: WorkloadMeta, spec: ClusterSpec, *,
                     devices=None, overlap: float = 0.5,
                     search_kw: dict | None = None):
    """Search ``spec`` and compile the winning plan over ``devices``.

    Returns ``(plan, candidate)``.  The placement is attached only on
    mixed-hardware clusters, keeping homogeneous plans byte-identical to
    the pre-heterogeneous planner (compile_plan's documented contract).
    """
    from repro.core.planner import mesh_for_strategy
    cand = search_cluster(meta, spec, overlap=overlap, search_kw=search_kw)
    mesh = mesh_for_strategy(cand.strategy, devices=devices,
                             cluster_spec=spec)
    plan = compile_plan(
        model, mesh, strategy=cand.strategy, cluster_spec=spec,
        workload_meta=meta,
        placement=None if spec.is_homogeneous else cand.placement,
        overlap=overlap)
    return plan, cand


@dataclasses.dataclass
class ElasticContext:
    """Rebuild (plan, params, opt_state) from a checkpoint on a new mesh."""
    model: Any
    optimizer: Any

    def remesh(self, ckpt: CheckpointManager, new_mesh,
               strategy: StrategySpec | None = None, *,
               cluster_spec: ClusterSpec | None = None,
               workload_meta: WorkloadMeta | None = None,
               placement=None, overlap: float = 0.0):
        """→ (step, plan, params, opt_state, extra) on ``new_mesh``.

        ``cluster_spec`` + ``workload_meta`` make the rebuilt plan carry a
        balanced heterogeneous placement (per-group batch shares) when the
        new hardware is mixed; a pre-computed ``placement`` (from the
        search) is attached as-is.  Raises FileNotFoundError when no
        committed checkpoint exists.
        """
        plan = compile_plan(self.model, new_mesh, strategy=strategy,
                            cluster_spec=cluster_spec,
                            workload_meta=workload_meta,
                            placement=placement, overlap=overlap)
        p_shapes = plan.param_shapes
        o_shapes = jax.eval_shape(self.optimizer.init, p_shapes)
        target = {"params": p_shapes, "opt": o_shapes}
        shardings = {
            "params": _ns(new_mesh, plan.param_specs),
            "opt": _ns(new_mesh, plan.opt_specs(self.optimizer)),
        }
        out = ckpt.restore_latest(target, shardings=shardings)
        if out is None:
            raise FileNotFoundError(
                f"no committed checkpoint in {ckpt.directory}")
        step, tree, extra = out
        return step, plan, tree["params"], tree["opt"], extra

    def rebalance(self, ckpt: CheckpointManager,
                  cluster_spec: ClusterSpec,
                  workload_meta: WorkloadMeta, *, new_mesh=None,
                  devices=None, overlap: float = 0.5,
                  search_kw: dict | None = None,
                  hardware: dict | None = None):
        """Re-mesh onto a **different hardware mix**.

        Runs the heterogeneity-aware strategy search over ``cluster_spec``
        (slowest-group-dominates cost, per-group HBM pruning), then
        restores the checkpoint into the winning plan — which carries the
        exact placement the search scored (not a re-balance at different
        assumptions).  The plan's ``placement.batch_slices()`` tells the
        data loader each group's new throughput-proportional share of the
        (unchanged) global batch.

        The winning strategy is only known after the search, so the mesh
        is normally built here (``new_mesh=None``) — over ``devices`` when
        given (straggler eviction passes the *surviving* device list from
        :func:`shrink_devices`), else over all of ``jax.devices()``.  A
        caller-supplied mesh is validated against the winner — a mesh
        realising a different (dp, tp, pp) would silently train a
        different parallelism than the placement describes.

        ``search_kw`` forwards to :func:`repro.core.auto.search` (e.g.
        ``max_pp=1`` to stay in the checkpoint's non-pipelined parameter
        layout — pipelined plans pad params per stage, so a live re-plan
        across that boundary would need a layout migration).

        ``hardware`` maps device-group names to replacement ``Hardware``
        tables (typically :class:`~repro.core.calibrate.CalibratedHardware`
        from the profiler): the search and the resulting placement then
        price with *measured* rates — the drift-triggered continuous
        rebalance path (DESIGN.md §10).  Groups not named keep their
        prior table.
        """
        from repro.core.planner import mesh_for_strategy
        if hardware:
            from repro.core.calibrate import refit_spec
            cluster_spec = refit_spec(cluster_spec, hardware)
        cand = search_cluster(workload_meta, cluster_spec, overlap=overlap,
                              search_kw=search_kw)
        strat = cand.strategy
        if new_mesh is None:
            new_mesh = mesh_for_strategy(strat, devices=devices,
                                         cluster_spec=cluster_spec)
        else:
            dp = 1
            for a in ("pod", "data"):
                if a in new_mesh.shape:
                    dp *= new_mesh.shape[a]
            realized = (dp, new_mesh.shape.get("model", 1),
                        new_mesh.shape.get("stage", 1))
            if realized != (strat.dp, strat.tp, strat.pp):
                raise ValueError(
                    f"new_mesh realises dp×tp×pp={realized} but the "
                    f"search picked {strat.describe()} — build the mesh "
                    f"with mesh_for_strategy(strategy) or omit new_mesh")
        return self.remesh(ckpt, new_mesh, strategy=strat,
                           cluster_spec=cluster_spec,
                           workload_meta=workload_meta,
                           placement=(None if cluster_spec.is_homogeneous
                                      else cand.placement), overlap=overlap)


def shrink_devices(devices, exclude_hosts: set, *, topology=None,
                   host_of=None):
    """Filter a device list to exclude flagged hosts (straggler eviction).

    Host-keyed, like :meth:`HostTopology.without`: pass ``topology`` (a
    :class:`HostTopology`) to use the simulated device→host mapping, or
    nothing to use the real multi-process mapping
    (``device.process_index``).

    .. deprecated::
        The ``host_of`` *callable* form is deprecated — it was the one
        API in the eviction path keyed on a mapping function rather than
        on hosts, and callers had to know to pass ``topology.host_of``
        bound methods.  Pass ``topology=`` instead.
    """
    if host_of is not None:
        warnings.warn(
            "shrink_devices(host_of=) is deprecated: pass "
            "topology=HostTopology(...) — the eviction APIs are keyed on "
            "hosts (like HostTopology.without), not on mapping callables",
            DeprecationWarning, stacklevel=2)
    elif topology is not None:
        host_of = topology.host_of
    else:
        host_of = (lambda d: d.process_index)
    exclude = set(exclude_hosts)
    return [d for d in devices if host_of(d) not in exclude]


def grow_devices(devices, new_hosts, *, topology):
    """Device list after admitting ``new_hosts`` (grow counterpart of
    :func:`shrink_devices`).

    ``new_hosts`` are :class:`SimHost` entries joining ``topology``
    (host-keyed, like :meth:`HostTopology.with_host` — duplicate ids and
    overlapping explicit offsets are loud errors); ``devices`` is the
    flat backing list (``jax.devices()``).  Returns ``(device_list,
    grown_topology)`` so the caller can re-mesh over exactly the devices
    the grown topology owns.
    """
    grown = topology
    for h in new_hosts:
        grown = grown.with_host(h)
    return grown.devices(devices), grown


# ---------------------------------------------------------------------------
# simulated multi-host topology (single-process stand-in for a fleet)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SimHost:
    """One simulated host: ``n_devices`` consecutive devices of one kind.

    ``offset`` is the host's first index into the flat device list; it is
    assigned by :class:`HostTopology` (declaration-order packing) and
    **preserved across eviction**, so a surviving host keeps its original
    physical devices rather than sliding down onto the evicted host's.
    """
    host: int
    hw: Any                    # core.cost_model.Hardware
    n_devices: int
    offset: int = -1           # assigned by HostTopology when < 0


@dataclasses.dataclass(frozen=True)
class HostTopology:
    """Partition the flat ``jax.devices()`` list into simulated hosts.

    On a real fleet ``device.process_index`` names the host; in the
    single-process harness devices are dealt to hosts in declaration
    order (host 0 gets the first ``n_devices`` devices, …).  The
    topology is the controller's source of truth for

    - ``cluster_spec()``: the per-hardware-group view the cost model and
      hetero balancer consume (consecutive same-hardware hosts merge
      into one :class:`DeviceGroup`),
    - ``host_of``: device → host id (feeds :func:`shrink_devices`),
    - ``without(hosts)``: the surviving topology after eviction.
    """
    hosts: tuple

    def __post_init__(self):
        fixed, off = [], 0
        for h in self.hosts:
            if h.offset < 0:
                h = dataclasses.replace(h, offset=off)
            fixed.append(h)
            off = h.offset + h.n_devices
        object.__setattr__(self, "hosts", tuple(fixed))

    @classmethod
    def uniform(cls, n_hosts: int, devices_per_host: int, hw
                ) -> "HostTopology":
        return cls(hosts=tuple(SimHost(h, hw, devices_per_host)
                               for h in range(n_hosts)))

    @property
    def n_devices(self) -> int:
        return sum(h.n_devices for h in self.hosts)

    @property
    def host_ids(self) -> tuple:
        return tuple(h.host for h in self.hosts)

    def host_of(self, device) -> int:
        """Map a device (by position in the flat device list) to its
        simulated host."""
        idx = device.id if hasattr(device, "id") else int(device)
        for h in self.hosts:
            if h.offset <= idx < h.offset + h.n_devices:
                return h.host
        raise ValueError(f"device index {idx} outside the topology's "
                         f"device ranges "
                         f"{[(h.offset, h.offset + h.n_devices) for h in self.hosts]}")

    def devices(self, all_devices, exclude: set = frozenset()) -> list:
        """The topology's device list minus excluded hosts (in host order).

        Each host contributes its *original* flat-device range — after an
        eviction the survivors keep their own hardware (the evicted
        host's devices are simply absent)."""
        need = max(h.offset + h.n_devices for h in self.hosts)
        if len(all_devices) < need:
            raise ValueError(
                f"topology wants device indices up to {need}, have "
                f"{len(all_devices)}")
        out = []
        for h in self.hosts:
            if h.host not in exclude:
                out.extend(all_devices[h.offset:h.offset + h.n_devices])
        return out

    def cluster_spec(self) -> ClusterSpec:
        """Per-group hardware view: consecutive same-hardware hosts merge."""
        from repro.core.cost_model import DeviceGroup
        groups = []
        for h in self.hosts:
            if groups and groups[-1].hw.name == h.hw.name:
                groups[-1] = dataclasses.replace(
                    groups[-1], n_devices=groups[-1].n_devices + h.n_devices)
            else:
                groups.append(DeviceGroup(
                    f"{h.hw.name}#{len(groups)}", h.hw, h.n_devices))
        return ClusterSpec(groups=tuple(groups))

    def group_hosts(self) -> dict:
        """``cluster_spec()`` group name → member host ids (same merge)."""
        out: dict = {}
        names: list = []
        for h in self.hosts:
            if names and names[-1][0] == h.hw.name:
                out[names[-1][1]].append(h.host)
            else:
                gname = f"{h.hw.name}#{len(names)}"
                names.append((h.hw.name, gname))
                out[gname] = [h.host]
        return out

    def without(self, evicted: set) -> "HostTopology":
        """The surviving topology after evicting ``evicted`` hosts."""
        keep = tuple(h for h in self.hosts if h.host not in evicted)
        if not keep:
            raise ValueError("eviction would remove every host")
        return HostTopology(hosts=keep)

    def with_host(self, host: SimHost) -> "HostTopology":
        """The grown topology after admitting ``host`` (grow counterpart
        of :meth:`without`).

        A ``host.offset < 0`` is placed **first-fit**: the lowest gap in
        the flat device index space that holds ``n_devices`` — so a
        re-admitted host reclaims the device range an eviction vacated
        rather than extending the flat list forever.  An explicit offset
        is honoured but must not overlap a live host's range.  Duplicate
        host ids and non-positive device counts are loud errors.
        """
        if host.n_devices <= 0:
            raise ValueError(
                f"host {host.host} offers n_devices={host.n_devices}; "
                "a joining host must bring at least one device")
        if host.host in self.host_ids:
            raise ValueError(
                f"host {host.host} is already a member "
                f"(hosts={self.host_ids}); evict it first or join under "
                "a fresh id")
        ranges = sorted((h.offset, h.offset + h.n_devices)
                        for h in self.hosts)
        if host.offset < 0:
            # first-fit: gaps between live ranges, then the tail
            cursor = 0
            placed = None
            for lo, hi in ranges:
                if lo - cursor >= host.n_devices:
                    placed = cursor
                    break
                cursor = max(cursor, hi)
            host = dataclasses.replace(
                host, offset=cursor if placed is None else placed)
        else:
            lo, hi = host.offset, host.offset + host.n_devices
            for rlo, rhi in ranges:
                if lo < rhi and rlo < hi:
                    raise ValueError(
                        f"host {host.host} requests device range "
                        f"[{lo}, {hi}) overlapping a live host's "
                        f"[{rlo}, {rhi})")
        grown = sorted(self.hosts + (host,), key=lambda h: h.offset)
        return HostTopology(hosts=tuple(grown))

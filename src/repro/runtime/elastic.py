"""Elastic re-meshing: restart the job at a different device count.

Checkpoints are mesh-agnostic (full logical arrays + logical axis names), so
scaling in/out is: build the new mesh → rebuild the plan (ShardingRules give
the new PartitionSpecs; divisibility pruning silently drops shardings that
no longer divide) → ``CheckpointManager.restore`` with the new shardings.
The batch schedule is kept consistent by preserving *global* batch size —
dp changes only the per-device slice.

This is the homogeneous-pod replacement for Whale-ATC'22's heterogeneous
load balancing (DESIGN.md §2): a flagged straggler host is excluded and the
job resumes on the surviving N−k hosts.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax

from repro.ckpt.checkpoint import CheckpointManager
from repro.core.planner import ExecutionPlan, compile_plan
from repro.core.cost_model import StrategySpec


def _ns(mesh, specs):
    import jax.sharding as shd
    return jax.tree.map(lambda s: shd.NamedSharding(mesh, s), specs,
                        is_leaf=lambda t: isinstance(t, shd.PartitionSpec))


@dataclasses.dataclass
class ElasticContext:
    """Rebuild (plan, params, opt_state) from a checkpoint on a new mesh."""
    model: Any
    optimizer: Any

    def remesh(self, ckpt: CheckpointManager, new_mesh,
               strategy: StrategySpec | None = None):
        """→ (step, plan, params, opt_state, extra) on ``new_mesh``.

        Raises FileNotFoundError when no committed checkpoint exists.
        """
        plan = compile_plan(self.model, new_mesh, strategy=strategy)
        p_shapes = plan.param_shapes
        o_shapes = jax.eval_shape(self.optimizer.init, p_shapes)
        target = {"params": p_shapes, "opt": o_shapes}
        shardings = {
            "params": _ns(new_mesh, plan.param_specs),
            "opt": _ns(new_mesh, plan.opt_specs(self.optimizer)),
        }
        out = ckpt.restore_latest(target, shardings=shardings)
        if out is None:
            raise FileNotFoundError(
                f"no committed checkpoint in {ckpt.directory}")
        step, tree, extra = out
        return step, plan, tree["params"], tree["opt"], extra


def shrink_devices(devices, exclude_hosts: set):
    """Filter a device list to exclude flagged hosts (straggler eviction)."""
    return [d for d in devices if d.process_index not in exclude_hosts]

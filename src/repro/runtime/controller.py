"""Event-driven cluster-membership runtime (DESIGN.md §12).

Whale's resource-adaptability story (§5) is bidirectional: a production
fleet both loses capacity (stragglers, spot reclaims, dead hosts) and
gains it (hosts joining, spot re-admission).  This module is the one
control loop that handles every case:

- **Typed events** — :class:`StragglerSustained`, :class:`DriftSustained`,
  :class:`PreemptionWarning`, :class:`HostLost`, :class:`HostJoin` — are
  produced by pluggable *sources* (:class:`StragglerSource` over the
  per-host monitors, :class:`DriftSource` over the predicted-vs-measured
  skew watch, :class:`InjectorSource` over the fault injector's scenario
  playback; a real deployment adds a scheduler-API source).
- **A small state machine** — RUNNING → DRAINING → REBALANCING → RESUMING
  → RUNNING, with terminal DONE / PREEMPTED / FAILED — serialises
  concurrent membership signals: events folding into the *pending*
  :class:`MembershipChange` while draining, deferring while a change is
  being applied, and raising :class:`IllegalTransition` everywhere else.
- **One apply path** — :meth:`ClusterController.apply_membership_change`
  is the only place the fleet reshapes: evictions shrink the
  :class:`~repro.runtime.elastic.HostTopology`, admissions grow it
  (``with_host``), recalibration re-fits the hardware tables, and the
  tail is identical for all of them — re-autotune kernel tiles, re-plan
  with the hetero-aware search, restore the committed checkpoint into
  the new plan, reshard the data stream, resume.  There is deliberately
  no evict-vs-grow branch anywhere else.

The drain discipline for spot reclaim: a :class:`PreemptionWarning`
carries the step deadline by which the host vanishes; the controller
stops the segment with a final synchronous checkpoint (one step — well
inside real spot notice windows), sheds the host, and re-plans on the
survivors.  If the host dies *before* the drain commits
(:class:`HostLost`), the in-flight state is untrusted: the loop aborts
**without** a final save and the apply path restores the last committed
checkpoint, replaying the lost steps exactly-once (the data pipeline
position is part of the checkpoint, and batches are a pure function of
the step).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any

import jax
import jax.numpy as jnp

from repro.ckpt.checkpoint import CheckpointManager
from repro.core.cost_model import step_cost, step_cost_features
from repro.data.pipeline import TokenPipeline
from repro.runtime.elastic import (ElasticContext, HostTopology, SimHost,
                                   plan_for_cluster)
from repro.runtime.fault_tolerance import FaultTolerantLoop
from repro.runtime.faults import FaultInjector
from repro.runtime.profiler import Profiler
from repro.runtime.straggler import HostStragglerAggregator


# ---------------------------------------------------------------------------
# typed cluster events
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ClusterEvent:
    """Base: something happened to the fleet at ``step``."""
    step: int


@dataclasses.dataclass(frozen=True)
class StragglerSustained(ClusterEvent):
    """``host`` has been a sustained step-time outlier (evict it)."""
    host: int
    dt: float = 0.0


@dataclasses.dataclass(frozen=True)
class DriftSustained(ClusterEvent):
    """Measured/predicted step-cost skew held above threshold (re-fit
    the hardware tables and re-plan; no host is evicted)."""
    skew: float


@dataclasses.dataclass(frozen=True)
class PreemptionWarning(ClusterEvent):
    """The scheduler reclaims ``host`` at ``deadline_step`` (spot/TPU
    maintenance notice): drain and shed it before then."""
    host: int
    deadline_step: int


@dataclasses.dataclass(frozen=True)
class HostLost(ClusterEvent):
    """``host`` vanished without a successful drain: the in-flight
    segment state is untrusted — fall back to the last committed
    checkpoint."""
    host: int


@dataclasses.dataclass(frozen=True)
class HostJoin(ClusterEvent):
    """``host`` (a :class:`SimHost`: id, hardware, device count) offers
    capacity — scale-up or spot re-admission."""
    host: SimHost


# ---------------------------------------------------------------------------
# the membership change a batch of events folds into
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MembershipChange:
    """The net fleet delta one REBALANCING pass applies.

    Events arriving while a segment drains merge here — a straggler flag
    and a preemption warning in the same segment become one evict set and
    one re-plan, not two serial rebalances.
    """
    evict: tuple = ()               # host ids leaving
    admit: tuple = ()               # SimHosts joining
    recalibrate: float = 0.0        # sustained skew (0.0 = no re-fit)
    abort: bool = False             # drain failed: restore last commit
    deadline_step: int | None = None
    reasons: tuple = ()             # event class names, for the log

    @property
    def is_noop(self) -> bool:
        return not (self.evict or self.admit or self.recalibrate)

    def merged(self, other: "MembershipChange") -> "MembershipChange":
        admit = list(self.admit)
        admit += [h for h in other.admit
                  if h.host not in {a.host for a in admit}]
        deadlines = [d for d in (self.deadline_step, other.deadline_step)
                     if d is not None]
        return MembershipChange(
            evict=tuple(dict.fromkeys(self.evict + other.evict)),
            admit=tuple(admit),
            recalibrate=max(self.recalibrate, other.recalibrate),
            abort=self.abort or other.abort,
            deadline_step=min(deadlines) if deadlines else None,
            reasons=self.reasons + other.reasons)


def change_for(event: ClusterEvent) -> MembershipChange:
    """The membership delta one event implies (pure; policy lives in
    :meth:`ClusterController._accept`)."""
    reason = (type(event).__name__,)
    if isinstance(event, StragglerSustained):
        return MembershipChange(evict=(event.host,), reasons=reason)
    if isinstance(event, DriftSustained):
        return MembershipChange(recalibrate=event.skew, reasons=reason)
    if isinstance(event, PreemptionWarning):
        return MembershipChange(evict=(event.host,),
                                deadline_step=event.deadline_step,
                                reasons=reason)
    if isinstance(event, HostLost):
        return MembershipChange(evict=(event.host,), abort=True,
                                reasons=reason)
    if isinstance(event, HostJoin):
        return MembershipChange(admit=(event.host,), reasons=reason)
    raise TypeError(f"not a ClusterEvent: {event!r}")


# ---------------------------------------------------------------------------
# state machine
# ---------------------------------------------------------------------------

RUNNING = "RUNNING"
DRAINING = "DRAINING"
REBALANCING = "REBALANCING"
RESUMING = "RESUMING"
DONE = "DONE"
PREEMPTED = "PREEMPTED"
FAILED = "FAILED"

TERMINAL = frozenset({DONE, PREEMPTED, FAILED})

_TRANSITIONS = {
    RUNNING: frozenset({DRAINING, DONE, PREEMPTED, FAILED}),
    DRAINING: frozenset({REBALANCING, DONE, PREEMPTED, FAILED}),
    REBALANCING: frozenset({RESUMING, FAILED}),
    RESUMING: frozenset({RUNNING, FAILED}),
    DONE: frozenset(),
    PREEMPTED: frozenset(),
    FAILED: frozenset(),
}


class IllegalTransition(RuntimeError):
    """A state change (or an event delivery) the machine forbids."""


@dataclasses.dataclass
class MembershipStateMachine:
    """Pure control state: where the run is, and what change is pending.

    ``on_event`` folds a :class:`ClusterEvent` in according to the
    current state — RUNNING starts a drain, DRAINING merges, REBALANCING
    and RESUMING defer the event to the next segment (a change is being
    applied; topology-relative decisions would race it), and terminal
    states raise.  The controller owns *policy* (budgets, min-hosts);
    the machine owns *sequencing*.
    """
    state: str = RUNNING
    pending: MembershipChange = dataclasses.field(
        default_factory=MembershipChange)
    deferred: tuple = ()

    def to(self, new_state: str) -> None:
        if new_state not in _TRANSITIONS[self.state]:
            raise IllegalTransition(
                f"{self.state} → {new_state} is not a legal controller "
                f"transition (allowed: "
                f"{sorted(_TRANSITIONS[self.state]) or 'none — terminal'})")
        self.state = new_state

    def on_event(self, event: ClusterEvent) -> bool:
        """Fold ``event`` in; True when the running segment must stop."""
        if self.state in TERMINAL:
            raise IllegalTransition(
                f"{type(event).__name__} delivered in terminal state "
                f"{self.state}")
        if self.state in (REBALANCING, RESUMING):
            self.deferred = self.deferred + (event,)
            return False
        self.pending = self.pending.merged(change_for(event))
        if self.state == RUNNING:
            self.to(DRAINING)
        return True

    def take(self) -> MembershipChange:
        """The pending change, clearing it (DRAINING → REBALANCING)."""
        change, self.pending = self.pending, MembershipChange()
        return change

    def take_deferred(self) -> tuple:
        events, self.deferred = self.deferred, ()
        return events


# ---------------------------------------------------------------------------
# event sources
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class StragglerSource:
    """Per-host sustained-outlier detection → :class:`StragglerSustained`."""
    aggregator: HostStragglerAggregator

    def poll(self, step: int, times: dict, topology: HostTopology) -> list:
        return [StragglerSustained(step=step, host=h, dt=times[h])
                for h in self.aggregator.observe(times)]


@dataclasses.dataclass
class DriftSource:
    """Predicted-vs-measured skew watch (DESIGN.md §10) →
    :class:`DriftSustained`.

    The first ``min_steps`` measured steps of each plan segment anchor
    the cost model's time scale (absorbing the clock's units and the
    constant modelling bias); afterwards each step feeds the profiler
    per-group observations in anchored units and ``patience`` consecutive
    steps with relative skew above ``1 + skew`` fire the event, once per
    segment.  :meth:`rearm` resets for the next plan.
    """
    cfg: "CalibrationConfig"
    profiler: Profiler

    def __post_init__(self):
        self.rearm({}, 0.0)

    def rearm(self, features: dict, predicted: float) -> None:
        self._feats = features
        self._pred = predicted
        self._n = 0
        self._sum = 0.0
        self._anchor = None
        self._hot = 0
        self._fired = False

    def poll(self, step: int, times: dict, topology: HostTopology) -> list:
        if self._fired or self._pred <= 0.0:
            return []
        measured = max(times.values())
        self._n += 1
        if self._n <= self.cfg.min_steps:
            self._sum += measured
            if self._n == self.cfg.min_steps:
                self._anchor = (self._sum / self.cfg.min_steps) / self._pred
            return []
        for gname, (feats, _pred, members) in self._feats.items():
            t_g = max((times[h] for h in members if h in times), default=0.0)
            if t_g > 0.0:
                self.profiler.record_step(gname, t_g / self._anchor, feats,
                                          step=step)
        skew = measured / (self._pred * self._anchor)
        self._hot = self._hot + 1 if skew > 1.0 + self.cfg.skew else 0
        if self._hot >= self.cfg.patience:
            self._fired = True
            return [DriftSustained(step=step, skew=skew)]
        return []


@dataclasses.dataclass
class InjectorSource:
    """Scenario playback → membership events (spot warn/lost, joins).

    The injector fires each signal exactly once; this source grounds it
    against the *live* topology — a host shed before its deadline never
    emits :class:`HostLost`, and a join for an already-present host id is
    dropped.
    """
    injector: FaultInjector
    default_hw: Any = None          # hardware for joins that name none

    def poll(self, step: int, times: dict, topology: HostTopology) -> list:
        events = []
        for kind, sc in self.injector.membership(step):
            if kind == "preempt_warn" and sc.host in topology.host_ids:
                events.append(PreemptionWarning(
                    step=step, host=sc.host,
                    deadline_step=sc.warn_step + sc.deadline_steps))
            elif kind == "host_lost" and sc.host in topology.host_ids:
                events.append(HostLost(step=step, host=sc.host))
            elif kind == "join" and sc.host not in topology.host_ids:
                events.append(HostJoin(step=step, host=SimHost(
                    sc.host, sc.hw or self.default_hw, sc.n_devices)))
        return events


# ---------------------------------------------------------------------------
# configuration
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class CalibrationConfig:
    """Knobs for the drift-triggered rebalance loop (DESIGN.md §10).

    The controller anchors the cost model's time scale to the first
    ``min_steps`` measured steps of each plan (median measured / predicted
    — absorbing the simulated clock's arbitrary units and constant
    modelling bias), then watches the *relative* skew
    ``measured / (predicted · anchor)``.  ``patience`` consecutive steps
    above ``1 + skew`` trigger a recalibration: the profiler's windowed
    observations re-fit each group's ``Hardware`` table and
    ``ElasticContext.rebalance(hardware=...)`` re-plans with measured
    rates — no host is evicted.  ``max_rebalances=0`` records
    observations (``--profile``) without ever rebalancing.
    """
    skew: float = 0.25
    patience: int = 5
    min_steps: int = 8
    window: int = 256               # observations per group fed to each fit
    max_rebalances: int = 2


@dataclasses.dataclass
class ElasticConfig:
    """Knobs for the self-healing loop (DESIGN.md §7, §12)."""
    topology: HostTopology
    threshold: float = 2.0          # straggler flag at mean + k·std
    patience: int = 3               # sustained outlier steps before flagging
    warmup: int = 5                 # per-monitor warmup (compile steps)
    min_hosts: int = 1              # never evict below this
    max_rebalances: int = 2         # then ride out the degradation
    overlap: float = 0.5            # comm/compute overlap for the search
    search_kw: dict = dataclasses.field(
        # stay in the checkpoint's non-pipelined parameter layout: a live
        # re-plan into a padded pipeline layout would need a migration
        default_factory=lambda: {"max_pp": 1})
    # predicted-vs-measured drift detection (None = off)
    calibration: CalibrationConfig | None = None


# ---------------------------------------------------------------------------
# the controller
# ---------------------------------------------------------------------------

class ClusterController:
    """Elastic training under cluster-membership churn.

    State machine (``.phase``)::

        RUNNING ──accepted event──▶ DRAINING ──stop+ckpt──▶ REBALANCING
           ▲                                                     │
           └── RESUMING ◀── restore into the re-planned mesh ────┘
        terminal: DONE (n_steps reached) | PREEMPTED (SIGTERM, final ckpt
        committed — a relaunch auto-resumes) | FAILED (retry budget
        exhausted and re-raise, after a final checkpoint)

    One :class:`FaultTolerantLoop` segment runs per plan; per-host step
    times (real, or synthesized by a
    :class:`~repro.runtime.faults.FaultInjector` on the simulated
    multi-host clock) feed the event sources each step, and any accepted
    event drains the segment — normally with a final synchronous
    checkpoint, or *without* one when the change says the state is
    untrusted (:class:`HostLost`).  Every membership delta then flows
    through :meth:`apply_membership_change`, shrink and grow alike.

    Batches are fetched idempotently per step (a retried step replays the
    *same* batch — the bounded-retry path cannot skip samples), and the
    data stream's content is drawn at global-batch granularity, so the
    sample stream is invariant across host-count changes in either
    direction.
    """

    def __init__(self, model, cfg, optimizer, data: TokenPipeline,
                 ckpt: CheckpointManager, *, elastic: ElasticConfig,
                 batch: int, seq: int, save_every: int = 50,
                 max_retries: int = 3, injector: FaultInjector | None = None,
                 log_every: int = 10, verbose: bool = True):
        self.model = model
        self.cfg = cfg
        self.optimizer = optimizer
        self.data = data
        self.ckpt = ckpt
        self.elastic = elastic
        self.topology = elastic.topology
        # flattened for the elastic search (max_pp=1 default: segment
        # boundaries are irrelevant to a pure DP/TP re-plan)
        self.meta = model.graph(batch, seq).workload_meta()
        self.save_every = save_every
        self.max_retries = max_retries
        self.injector = injector
        self.log_every = log_every
        self.verbose = verbose
        self.machine = MembershipStateMachine()
        self.events: list = []
        self.losses: list = []
        self.calibration = elastic.calibration
        self.profiler = Profiler()
        self.aggregator = HostStragglerAggregator(
            n_hosts=len(self.topology.hosts),
            threshold=elastic.threshold, patience=elastic.patience,
            warmup=elastic.warmup)
        self.aggregator.reset(self.topology.host_ids)
        self.sources: list = [StragglerSource(self.aggregator)]
        self.drift_source = None
        if self.calibration is not None:
            self.drift_source = DriftSource(self.calibration, self.profiler)
            self.sources.append(self.drift_source)
        if injector is not None:
            self.sources.append(InjectorSource(
                injector, default_hw=self.topology.hosts[0].hw))
        self._rebalances = 0
        self._recalibrations = 0
        self._batch_step = -1
        self._batch = None
        self._data_state_before = None

    @property
    def phase(self) -> str:
        return self.machine.state

    # ------------------------------------------------------------- logging
    def _log(self, msg: str) -> None:
        if self.verbose:
            print(msg)

    def _event(self, kind: str, **kw) -> None:
        self.events.append({"kind": kind, **kw})

    # ------------------------------------------------------------ planning
    def _plan_current(self):
        """Search the current cluster and compile the plan + mesh."""
        plan, cand = plan_for_cluster(
            self.model, self.meta, self.topology.cluster_spec(),
            devices=self.topology.devices(jax.devices()),
            overlap=self.elastic.overlap, search_kw=self.elastic.search_kw)
        return plan, float(cand.total)

    def _predicted_total(self, plan) -> float:
        """The cost model's step-time prediction for the current plan."""
        if plan.placement is not None:
            return float(plan.placement.cost.total)
        g = self.topology.cluster_spec().groups[0]
        return float(step_cost(self.meta, plan.strategy, g.hw,
                               overlap=self.elastic.overlap).total)

    def _group_features(self, plan) -> dict:
        """Per device group: (calibration features, predicted s, hosts).

        The features (``cost_model.step_cost_features`` of the group's
        unit of work) are what the profiler attaches to each measured
        group step time, so ``calibrate.fit`` can invert them back into
        ``Hardware`` rates.
        """
        members = self.topology.group_hosts()
        ov = self.elastic.overlap
        out = {}
        if plan.placement is not None:
            for u in plan.placement.units:
                if u.kind != "group":
                    continue
                out[u.group.name] = (
                    step_cost_features(u.meta, u.strategy, u.group.hw,
                                       overlap=ov),
                    float(u.cost.total), members.get(u.group.name, []))
        else:
            g = self.topology.cluster_spec().groups[0]
            out[g.name] = (
                step_cost_features(self.meta, plan.strategy, g.hw,
                                   overlap=ov),
                float(step_cost(self.meta, plan.strategy, g.hw,
                                overlap=ov).total),
                members.get(g.name, list(self.topology.host_ids)))
        return out

    def _retune_model(self, spec) -> None:
        """Re-autotune kernel tiles for ``spec`` and rebuild the model.

        Plans re-run the tile autotuner inside ``compile_plan``, but the
        *executing model* bakes block sizes into its config at startup —
        after a membership change alters the hardware mix (evict/admit)
        or the rates (recalibration), those baked tiles are stale.  Tiles
        don't change parameter shapes, so the rebuilt model restores the
        same checkpoint.
        """
        cfg = self.cfg
        if "pallas" not in (cfg.attn_impl, cfg.xent_impl, cfg.ssd_impl):
            return
        if not getattr(cfg, "n_heads", 0):
            return
        from repro.kernels.autotune import DEFAULT_TILES, autotune_cluster
        tiles_by_group = autotune_cluster(
            spec, head_dim=cfg.hd,
            group=cfg.n_heads // max(cfg.n_kv_heads, 1) or 1,
            d_model=cfg.d_model, vocab=cfg.padded_vocab)
        tiles = list(tiles_by_group.values())
        lo = tiles[0] if tiles else DEFAULT_TILES
        for t in tiles[1:]:                 # min over groups: fits everywhere
            lo = dataclasses.replace(lo, **{
                f.name: min(getattr(lo, f.name), getattr(t, f.name))
                for f in dataclasses.fields(t)})
        new_cfg = dataclasses.replace(
            cfg, attn_block_q=lo.block_q, attn_block_k=lo.block_k,
            xent_block_t=lo.xent_block_t, xent_block_v=lo.xent_block_v,
            ssd_chunk=(lo.ssd_chunk if cfg.family in ("ssm", "hybrid")
                       else cfg.ssd_chunk))
        if new_cfg != cfg:
            from repro.models.lm import build
            self.cfg = new_cfg
            self.model = build(new_cfg)
            self._event("retune", tiles=str(lo))
            self._log(f"[retune] kernel tiles re-sized for "
                      f"{'+'.join(g.name for g in spec.groups)}: {lo}")

    # ------------------------------------------------- event policy
    def _accept(self, event: ClusterEvent) -> bool:
        """Policy: does this event get to change the fleet?

        The state machine sequences; this gates — budgets, floors, and
        feasibility.  Rejected events are logged and dropped (the fleet
        rides out the condition).
        """
        pending = self.machine.pending
        if isinstance(event, StragglerSustained):
            h = event.host
            self._event("flag", step=event.step, host=h, dt=event.dt,
                        mean=self.aggregator.monitors[h].mean
                        if h in self.aggregator.monitors else None)
            self._log(f"[straggler] host {h} flagged at step {event.step} "
                      f"(dt={event.dt:.3f}s)")
            survivors = (len(self.topology.hosts) - len(pending.evict) - 1)
            if survivors < self.elastic.min_hosts:
                self._log(f"[straggler] NOT evicting host {h}: "
                          f"{survivors} survivors < min_hosts="
                          f"{self.elastic.min_hosts}")
                return False
            if self._rebalances >= self.elastic.max_rebalances:
                self._log("[straggler] rebalance budget exhausted; "
                          "riding out the degradation")
                return False
            return True
        if isinstance(event, DriftSustained):
            if pending.evict:
                return False        # an eviction already drains; its
                                    # rebalance re-plans anyway
            if self._recalibrations >= (self.calibration.max_rebalances
                                        if self.calibration else 0):
                return False
            self._log(f"[drift] measured/predicted skew {event.skew:.2f} "
                      f"sustained {self.calibration.patience} steps at "
                      f"step {event.step}; stopping to recalibrate")
            return True
        if isinstance(event, PreemptionWarning):
            # forced: the scheduler takes the host whether we drain or not
            self._event("preempt_warn", step=event.step, host=event.host,
                        deadline_step=event.deadline_step)
            self._log(f"[preempt-warn] host {event.host} reclaimed by step "
                      f"{event.deadline_step}; draining at step "
                      f"{event.step}")
            return True
        if isinstance(event, HostLost):
            self._event("host_lost", step=event.step, host=event.host)
            self._log(f"[host-lost] host {event.host} vanished at step "
                      f"{event.step} before the drain committed; falling "
                      f"back to the last committed checkpoint")
            return True
        if isinstance(event, HostJoin):
            sh = event.host
            if self._rebalances >= self.elastic.max_rebalances:
                self._log(f"[join] NOT admitting host {sh.host}: rebalance "
                          f"budget exhausted")
                return False
            try:
                grown = self.topology.with_host(sh)
                for admitted in self.machine.pending.admit:
                    grown = grown.with_host(admitted)
                grown.devices(jax.devices())
            except ValueError as e:
                self._log(f"[join] NOT admitting host {sh.host}: {e}")
                return False
            self._log(f"[join] host {sh.host} offers {sh.n_devices}×"
                      f"{sh.hw.name} at step {event.step}; draining to "
                      f"grow")
            return True
        raise TypeError(f"not a ClusterEvent: {event!r}")

    def _dispatch(self, event: ClusterEvent,
                  loop: FaultTolerantLoop | None) -> None:
        if not self._accept(event):
            return
        self.machine.on_event(event)
        if loop is not None and self.machine.state == DRAINING:
            if self.machine.pending.abort:
                loop.request_abort()    # state untrusted: no final save
            else:
                loop.request_stop()     # drain with a final sync ckpt

    # --------------------------------------------- unified membership path
    def apply_membership_change(self, change: MembershipChange, *,
                                at_step: int) -> tuple:
        """THE one path every fleet reshape takes (shrink, grow, re-fit).

        Evictions shrink the topology, admissions grow it, recalibration
        re-fits the hardware tables from profiler observations — then one
        shared tail: re-autotune kernel tiles for the new mix, re-plan
        with the hetero-aware search, restore the committed checkpoint
        into the new plan (for an aborted drain that checkpoint predates
        ``at_step`` — the lost steps replay exactly-once), reshard the
        data stream, reset the monitors.  Returns
        ``(step, plan, state)``.
        """
        if self.machine.state != REBALANCING:
            raise IllegalTransition(
                f"apply_membership_change outside REBALANCING "
                f"(state {self.machine.state})")
        if change.is_noop:
            raise ValueError("refusing to rebalance on a no-op "
                             "MembershipChange")
        hardware = None
        if change.evict:
            for h in change.evict:
                self.aggregator.evict(h)
            self.topology = self.topology.without(set(change.evict))
            self._event("evict", step=at_step, hosts=list(change.evict),
                        surviving_devices=self.topology.n_devices)
            self._log(f"[evict] hosts {list(change.evict)} at step "
                      f"{at_step}; rebalancing onto "
                      f"{self.topology.n_devices} devices")
        if change.admit:
            for sh in change.admit:
                self.topology = self.topology.with_host(sh)
                self.aggregator.admit(sh.host)
            self._event("join", step=at_step,
                        hosts=[sh.host for sh in change.admit],
                        total_devices=self.topology.n_devices)
            self._log(f"[join] hosts {[sh.host for sh in change.admit]} "
                      f"at step {at_step}; rebalancing onto "
                      f"{self.topology.n_devices} devices")
        tune_spec = self.topology.cluster_spec()
        if change.recalibrate and not (change.evict or change.admit):
            # drift-triggered recalibration: same fleet, re-fitted
            # Hardware tables — continuous rebalancing (DESIGN.md §10)
            tune_spec, hardware = self.profiler.fit_spec(
                self.topology.cluster_spec(),
                last_n=self.calibration.window)
            self._event("drift", step=at_step, skew=change.recalibrate,
                        hardware={
                            n: {"eff_flops": h.peak_flops * h.mxu_eff,
                                "n_obs": h.n_observations}
                            for n, h in hardware.items()})
            self._log(f"[drift] recalibrating at step {at_step} "
                      f"(skew {change.recalibrate:.2f}); re-planning with "
                      f"measured rates")
        # stale-tiles fix: the executing model baked kernel tiles for the
        # old mix/rates — re-autotune before re-meshing
        self._retune_model(tune_spec)
        ectx = ElasticContext(model=self.model, optimizer=self.optimizer)
        t0 = time.monotonic()
        step, plan, params, opt_state, extra = ectx.rebalance(
            self.ckpt, self.topology.cluster_spec(), self.meta,
            devices=self.topology.devices(jax.devices()),
            overlap=self.elastic.overlap,
            search_kw=self.elastic.search_kw,
            hardware=hardware)
        if "data" in extra:
            self.data.load_state_dict(extra["data"])
        self._reshard_data()
        self._batch_step, self._batch = step - 1, None
        state = {"params": params, "opt": opt_state}
        if change.evict or change.admit:
            kind = "rebalance"
            self._rebalances += 1
            self.profiler.clear()   # old groups' names/shares are stale
        else:
            kind = "recalibrate"
            self._recalibrations += 1
        self.aggregator.reset(self.topology.host_ids)
        self._event(kind, step=step,
                    strategy=plan.strategy.describe(),
                    downtime_s=time.monotonic() - t0,
                    placement=(plan.placement.describe()
                               if plan.placement else None))
        self._log(f"[{kind}] resumed at step {step} with "
                  f"{plan.strategy.describe()}")
        return step, plan, state

    def _reshard_data(self) -> None:
        """Re-slice the data stream onto the new host count (both
        directions).  Content is drawn at global-batch granularity, so
        the global stream is invariant; the single-process harness
        consumes the global batch itself (1-of-1) and needs no
        re-slicing."""
        n_hosts = len(self.topology.hosts)
        if self.data.n_hosts <= 1 or self.data.n_hosts == n_hosts:
            return
        if self.data.cfg.global_batch % n_hosts:
            self._log(f"[reshard] keeping {self.data.n_hosts}-way data "
                      f"sharding: global_batch "
                      f"{self.data.cfg.global_batch} does not divide "
                      f"over {n_hosts} hosts")
            return
        host_id = min(self.data.host_id, n_hosts - 1)
        self.data = self.data.reshard(host_id=host_id, n_hosts=n_hosts)

    def _build_step_fn(self, plan):
        batch0 = {k: jnp.asarray(v) for k, v in self._peek_batch().items()}
        with plan.mesh:
            jfn = plan.jit_train_step(self.optimizer, batch0, donate=False)

        def one_step(i, st):
            if self.injector is not None:
                self.injector.maybe_preempt(i)
            batch = self._batch_for(i)
            if self.injector is not None:
                self.injector.maybe_fail(i)
            with plan.mesh:
                p, o, m = jfn(st["params"], st["opt"], batch,
                              jnp.asarray(i))
            self.losses.append(float(m["loss"]))
            if i % self.log_every == 0:
                self._log(f"  step {i:5d}  loss {self.losses[-1]:.4f}")
            return {"params": p, "opt": o}

        return one_step

    # -------------------------------------------------- exactly-once data
    def _peek_batch(self) -> dict:
        """The next step's batch (cached, so the step replays it)."""
        return self._batch_for(self._batch_step + 1)

    def _batch_for(self, step: int) -> dict:
        """Idempotent per-step batch: a retried step replays the same
        samples instead of silently consuming the next draw."""
        if step != self._batch_step:
            self._data_state_before = self.data.state_dict()
            raw = self.data.next_batch()
            self._batch = {k: jnp.asarray(v) for k, v in raw.items()}
            self._batch_step = step
        return self._batch

    def _data_state_at(self, step: int) -> dict:
        """The pipeline position with exactly ``step`` batches consumed —
        what a checkpoint committed at ``step`` must record.  A save at
        the *failed* step (retry budget exhausted) lands one batch behind
        the cursor, so the pre-fetch snapshot is returned instead."""
        consumed = self._batch_step + 1
        if step == self._batch_step and self._data_state_before is not None:
            return dict(self._data_state_before)
        if step != consumed:
            raise RuntimeError(
                f"data pipeline out of sync: checkpoint at step {step} but "
                f"{consumed} batches consumed")
        return self.data.state_dict()

    # ------------------------------------------------------------ the loop
    def run(self, n_steps: int, seed: int = 0) -> dict:
        plan, predicted = self._plan_current()
        self._log(f"[elastic] initial plan: "
                  f"{plan.strategy.describe()} on "
                  f"{self.topology.n_devices} devices "
                  f"(predicted {predicted*1e3:.1f} ms/step)")
        with plan.mesh:
            params = plan.init_params(jax.random.key(seed))
            opt_state = jax.jit(self.optimizer.init)(params)
        step = 0
        resume = self.ckpt.restore_latest({"params": params,
                                           "opt": opt_state})
        if resume is not None:
            step, tree, extra = resume
            params, opt_state = tree["params"], tree["opt"]
            if "data" in extra:
                self.data.load_state_dict(extra["data"])
                self._batch_step, self._batch = step - 1, None
            self._log(f"[resume] from step {step}")
        state = {"params": params, "opt": opt_state}

        while True:
            # membership signals that arrived while the last change was
            # applying re-enter the machine before the next segment runs
            for ev in self.machine.take_deferred():
                self._dispatch(ev, loop=None)
            if self.machine.state == RUNNING:
                if step >= n_steps:
                    break
                segment_start = step
                if self.drift_source is not None:
                    self.drift_source.rearm(self._group_features(plan),
                                            self._predicted_total(plan))
                loop = FaultTolerantLoop(self.ckpt,
                                         save_every=self.save_every,
                                         max_retries=self.max_retries)

                def on_step(i, st, dt, _loop=loop, _start=segment_start):
                    if i == _start:
                        return      # jit-compile step would poison warmup
                    hosts = self.topology.host_ids
                    if self.injector is not None:
                        times = self.injector.host_times(i, base=dt,
                                                         hosts=hosts)
                    else:
                        # single-process: every host reports the global
                        # step time; a real fleet reports per-host
                        # measurements
                        times = {h: dt for h in hosts}
                    for source in self.sources:
                        for ev in source.poll(i, times, self.topology):
                            self._dispatch(ev, loop=_loop)

                step_fn = self._build_step_fn(plan)
                try:
                    step, state = loop.run(
                        state=state, step_fn=step_fn, n_steps=n_steps,
                        start_step=step,
                        extra_fn=lambda st, s: {"data":
                                                self._data_state_at(s)},
                        on_step=on_step)
                except Exception:
                    self.machine.to(FAILED)
                    raise
                if loop.preempted:
                    self._event("preempted", step=step,
                                pending_evictions=list(
                                    self.machine.pending.evict))
                    self._log(f"[preempt] SIGTERM at step {step}; final "
                              f"checkpoint committed")
                    self.machine.to(PREEMPTED)
                    break
            if self.machine.state != DRAINING:
                break               # segment completed with nothing pending
            if step >= n_steps and not self.machine.pending.abort:
                # n_steps reached — an event raised on the very last step
                # must not trigger a rebalance whose result is discarded
                # (an abort is the exception: the tail was never
                # committed, so the change must apply and replay it)
                break
            change = self.machine.take()
            self.machine.to(REBALANCING)
            step, plan, state = self.apply_membership_change(
                change, at_step=step)
            self.machine.to(RESUMING)
            self.machine.to(RUNNING)
        if self.machine.state not in TERMINAL:
            self.machine.to(DONE)
        return {"final_step": step, "state": state, "events": self.events,
                "losses": self.losses, "phase": self.phase,
                "topology": self.topology}

"""Straggler detection: per-step timing, EMA outlier flagging, mitigation.

The ATC'22 Whale balances *heterogeneous* GPUs by skewing work; TPU pods are
homogeneous, so the production analogue (DESIGN.md §2, §7) is detecting a
*slow* host (failing HBM, thermal throttle, noisy neighbour on DCN) and
evicting it via elastic re-mesh.  The monitor keeps an EMA + variance of
step times and flags sustained outliers; in a multi-host deployment each
host reports its local step time and the controller aggregates
(single-process here: the aggregation path is exercised with synthetic
per-host timings from :mod:`repro.runtime.faults`).

Flag semantics are **one-shot**: :meth:`StragglerMonitor.observe` returns
True exactly once, on the step the sustained-outlier flag trips; the
``flagged`` attribute stays latched (queryable) until :meth:`reset`.  The
:class:`HostStragglerAggregator` additionally remembers evicted hosts so a
host that has already been handed to the eviction machinery is never
re-reported — the pre-fix behaviour re-flagged an evicted host on every
``observe`` call, which made the controller loop evict forever.
"""
from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass
class StragglerMonitor:
    ema_decay: float = 0.9
    threshold: float = 2.0        # flag when t > mean + threshold·std
    patience: int = 3             # consecutive outliers before flagging
    warmup: int = 5               # ignore the first steps (compile etc.)

    def __post_init__(self):
        self.reset(clear_stats=True)

    def reset(self, *, clear_stats: bool = False) -> None:
        """Re-arm the one-shot flag; ``clear_stats`` also restarts the
        timing statistics (use after a re-plan changes the step time)."""
        self.consecutive = 0
        self.flagged = False
        if clear_stats:
            self.mean = 0.0
            self.var = 0.0
            self._m2 = 0.0        # Welford sum of squared deviations
            self.n = 0

    def observe(self, dt: float) -> bool:
        """Record one step time; True exactly once, when the flag trips.

        After the flag trips the monitor latches (``flagged`` stays True,
        further observations are ignored) until :meth:`reset`.
        """
        self.n += 1
        if self.n <= self.warmup:
            # Welford: seed mean AND variance from the warmup samples so
            # the first post-warmup step is not compared against std == 0
            delta = dt - self.mean
            self.mean += delta / self.n
            self._m2 += delta * (dt - self.mean)
            if self.n >= 2:
                self.var = self._m2 / (self.n - 1)
            return False
        if self.flagged:
            return False          # latched; one-shot already consumed
        std = math.sqrt(max(self.var, 1e-12))
        is_out = dt > self.mean + self.threshold * max(std, 0.05 * self.mean)
        if is_out:
            self.consecutive += 1
        else:
            self.consecutive = 0
        if self.consecutive >= self.patience:
            self.flagged = True
            return True
        # EMA update (outliers excluded so one bad host can't drag the mean)
        if not is_out:
            d = self.ema_decay
            delta = dt - self.mean
            self.mean += (1 - d) * delta
            self.var = d * (self.var + (1 - d) * delta * delta)
        return False


@dataclasses.dataclass
class HostStragglerAggregator:
    """Controller view: one monitor per host; decides eviction.

    ``observe`` returns only *newly* flagged hosts (one-shot, like the
    monitors); hosts handed to :meth:`evict` are dropped entirely and
    silently ignored if their timings keep arriving (a dying host may
    emit a few more heartbeats before the re-mesh lands).
    """
    n_hosts: int
    threshold: float = 2.0
    patience: int = 3
    warmup: int = 5

    def __post_init__(self):
        self.monitors = {h: self._new_monitor() for h in range(self.n_hosts)}
        self.evicted: set = set()

    def _new_monitor(self) -> StragglerMonitor:
        return StragglerMonitor(threshold=self.threshold,
                                patience=self.patience, warmup=self.warmup)

    def observe(self, host_times: dict) -> list:
        """host_id → step time; returns hosts *newly* flagged for eviction."""
        flagged = []
        for h, t in host_times.items():
            mon = self.monitors.get(h)
            if mon is None:                 # evicted / unknown host
                continue
            if mon.observe(t):
                flagged.append(h)
        return flagged

    def evict(self, host: int) -> None:
        """Mark ``host`` as evicted; it is never reported again."""
        self.evicted.add(host)
        self.monitors.pop(host, None)

    def admit(self, host: int) -> None:
        """(Re-)admit ``host``: clear any eviction record and start a
        fresh monitor — a joining host (spot re-admission, scale-up) is
        healthy until its own timings say otherwise.  This is the only
        way an evicted host comes back; :meth:`reset` never resurrects
        one."""
        self.evicted.discard(host)
        self.monitors[host] = self._new_monitor()

    def reset(self, hosts=None) -> None:
        """Fresh monitors after a re-plan (step times change shape).

        ``hosts``: the surviving host ids; default = current non-evicted
        set.  Evicted hosts stay excluded.
        """
        if hosts is None:
            hosts = list(self.monitors)
        self.monitors = {h: self._new_monitor() for h in hosts
                         if h not in self.evicted}

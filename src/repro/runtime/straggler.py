"""Straggler detection: per-step timing, EMA outlier flagging, mitigation.

The ATC'22 Whale balances *heterogeneous* GPUs by skewing work; TPU pods are
homogeneous, so the production analogue (DESIGN.md §2) is detecting a *slow*
host (failing HBM, thermal throttle, noisy neighbour on DCN) and evicting it
via elastic re-mesh.  The monitor keeps an EMA + variance of step times and
flags sustained outliers; in a multi-host deployment each host reports its
local step time and the controller aggregates (single-process here: the
aggregation path is exercised with synthetic per-host timings in tests).
"""
from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass
class StragglerMonitor:
    ema_decay: float = 0.9
    threshold: float = 2.0        # flag when t > mean + threshold·std
    patience: int = 3             # consecutive outliers before flagging
    warmup: int = 5               # ignore the first steps (compile etc.)

    def __post_init__(self):
        self.mean = 0.0
        self.var = 0.0
        self.n = 0
        self.consecutive = 0
        self.flagged = False

    def observe(self, dt: float) -> bool:
        """Record one step time; returns True if a straggler is flagged."""
        self.n += 1
        if self.n <= self.warmup:
            self.mean = dt if self.n == 1 else (
                self.mean + (dt - self.mean) / self.n)
            return False
        std = math.sqrt(max(self.var, 1e-12))
        is_out = dt > self.mean + self.threshold * max(std, 0.05 * self.mean)
        if is_out:
            self.consecutive += 1
        else:
            self.consecutive = 0
        if self.consecutive >= self.patience:
            self.flagged = True
        # EMA update (outliers excluded so one bad host can't drag the mean)
        if not is_out:
            d = self.ema_decay
            delta = dt - self.mean
            self.mean += (1 - d) * delta
            self.var = d * (self.var + (1 - d) * delta * delta)
        return self.flagged


@dataclasses.dataclass
class HostStragglerAggregator:
    """Controller view: one monitor per host; decides eviction."""
    n_hosts: int
    threshold: float = 2.0
    patience: int = 3

    def __post_init__(self):
        self.monitors = {h: StragglerMonitor(threshold=self.threshold,
                                             patience=self.patience)
                         for h in range(self.n_hosts)}

    def observe(self, host_times: dict) -> list:
        """host_id → step time; returns hosts flagged for replacement."""
        flagged = []
        for h, t in host_times.items():
            if self.monitors[h].observe(t):
                flagged.append(h)
        return flagged

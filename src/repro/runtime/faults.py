"""Fault injection: deterministic failure scenarios on a simulated
multi-host clock.

The self-healing controller (DESIGN.md §7) is driven by three inputs that
on a real fleet come from the outside world: per-host step times, step
exceptions, and preemption signals.  This module synthesizes all three
deterministically so the straggler → evict → rebalance → resume loop can
be exercised end-to-end in a single process:

- :class:`SlowHost` — one host's step time is inflated by ``factor`` from
  ``start_step`` (optionally until ``end_step``): the failing-HBM /
  thermal-throttle / noisy-neighbour case that straggler eviction targets.
- :class:`DriftHost` — a *gradual* linear slowdown ramp that stays under
  the straggler monitor's outlier threshold; the case that drift-triggered
  recalibration (DESIGN.md §10) catches and one-shot eviction does not.
- :class:`CrashStep` — the step function raises a transient
  ``RuntimeError`` ``times`` times at ``step`` (DCN flake, preempted
  reduction); exercised against :class:`FaultTolerantLoop`'s bounded
  retry, which must replay the *same* batch (exactly-once data).
- :class:`Preemption` — SIGTERM is delivered to the process before
  ``step`` (TPU maintenance events), exercising the final-synchronous-
  checkpoint path.
- :class:`SpotPreemption` — the *membership* flavour (DESIGN.md §12): a
  spot reclaim notice for one host at ``warn_step`` with the host
  vanishing ``deadline_steps`` later, exercising the controller's
  drain-within-deadline path (and the fall-back-to-last-checkpoint path
  when the deadline is missed).
- :class:`JoinHost` — a host *offers* capacity at ``step`` (scale-up /
  spot re-admission), exercising the symmetric grow path.

Per-host times are a pure function of ``(seed, step, host)`` — the same
scenario always produces the same timeline, so tests and
``benchmarks/fig_elastic.py`` / ``benchmarks/fig_spot.py`` are
deterministic.
"""
from __future__ import annotations

import dataclasses
import os
import signal
from typing import Any

import numpy as np


@dataclasses.dataclass(frozen=True)
class SlowHost:
    """Host ``host`` runs ``factor``× slower from ``start_step`` on."""
    host: int
    start_step: int
    factor: float = 3.0
    end_step: int | None = None     # None = slow forever (until evicted)

    def active(self, step: int) -> bool:
        return (step >= self.start_step
                and (self.end_step is None or step < self.end_step))


@dataclasses.dataclass(frozen=True)
class DriftHost:
    """Host ``host`` slows *gradually*: 1× at ``start_step`` ramping
    linearly to ``factor``× at ``end_step``, then holding.

    The calibration adversary (DESIGN.md §10): a slow ramp stays inside
    the straggler monitor's outlier band at every individual step (the
    EMA tracks the drift), so one-shot eviction never fires — only the
    predicted-vs-measured skew accumulated by the profiler exposes it.
    """
    host: int
    start_step: int
    end_step: int
    factor: float = 3.0

    def factor_at(self, step: int) -> float:
        if step <= self.start_step:
            return 1.0
        if step >= self.end_step:
            return self.factor
        frac = (step - self.start_step) / (self.end_step - self.start_step)
        return 1.0 + frac * (self.factor - 1.0)


@dataclasses.dataclass(frozen=True)
class CrashStep:
    """The step raises a transient error ``times`` times at ``step``."""
    step: int
    times: int = 1
    message: str = "injected transient step failure"


@dataclasses.dataclass(frozen=True)
class Preemption:
    """SIGTERM is delivered immediately before ``step`` runs."""
    step: int


@dataclasses.dataclass(frozen=True)
class SpotPreemption:
    """Spot reclaim: the scheduler warns at ``warn_step`` that ``host``
    disappears ``deadline_steps`` later.

    ``deadline_steps=0`` models a missed/zero notice — the warning and
    the loss land on the same step, so the controller cannot commit a
    drain checkpoint and must fall back to the last committed one.
    """
    host: int
    warn_step: int
    deadline_steps: int = 2


@dataclasses.dataclass(frozen=True)
class JoinHost:
    """A host offers ``n_devices`` devices of ``hw`` from ``step`` on
    (scale-up, or a spot pool re-admitting reclaimed capacity).  A
    ``hw`` of None takes the consuming fleet's default hardware."""
    host: int
    step: int
    n_devices: int
    hw: Any = None


@dataclasses.dataclass
class FaultInjector:
    """Deterministic scenario playback for the training controller.

    ``host_times(step, base)`` is the simulated multi-host clock: every
    host reports ``base`` (the measured or nominal step time) perturbed
    by a small deterministic jitter, with active :class:`SlowHost`
    scenarios multiplied in.  ``maybe_fail`` / ``maybe_preempt`` are
    called by the controller's step function / loop hooks.
    """
    scenarios: tuple = ()
    n_hosts: int = 1
    jitter: float = 0.02            # relative σ of per-host noise
    seed: int = 0
    # nominal step time: when set, host_times ignores the measured base
    # entirely — the whole timeline becomes a pure function of (seed,
    # step, host), immune to load spikes on the machine running the
    # simulation (CI runners flagging the wrong host)
    nominal: float | None = None

    def __post_init__(self):
        self.scenarios = tuple(self.scenarios)
        self._crash_budget = {
            id(s): s.times for s in self.scenarios
            if isinstance(s, CrashStep)}
        self._preempted: set = set()
        self._membership_fired: set = set()

    # --- simulated multi-host clock ---
    def slow_factor(self, step: int, host: int) -> float:
        f = 1.0
        for s in self.scenarios:
            if isinstance(s, SlowHost) and s.host == host and s.active(step):
                f *= s.factor
            elif isinstance(s, DriftHost) and s.host == host:
                f *= s.factor_at(step)
        return f

    def host_times(self, step: int, base: float = 1.0,
                   hosts=None) -> dict:
        """host_id → simulated step time at ``step``.

        Deterministic in ``(seed, step, host)``: replaying a scenario
        (e.g. the naive vs self-healing arms of fig_elastic) sees the
        identical timeline.
        """
        if self.nominal is not None:
            base = self.nominal
        hosts = range(self.n_hosts) if hosts is None else hosts
        out = {}
        for h in hosts:
            rng = np.random.default_rng(
                (self.seed * 1_000_003 + step) * 1_000_003 + h)
            noise = 1.0 + self.jitter * float(rng.standard_normal())
            out[h] = base * max(noise, 0.1) * self.slow_factor(step, h)
        return out

    # --- step failures ---
    def maybe_fail(self, step: int) -> None:
        """Raise the scenario's transient error while its budget lasts."""
        for s in self.scenarios:
            if isinstance(s, CrashStep) and s.step == step:
                if self._crash_budget.get(id(s), 0) > 0:
                    self._crash_budget[id(s)] -= 1
                    raise RuntimeError(f"{s.message} (step {step})")

    # --- cluster membership (DESIGN.md §12) ---
    def membership(self, step: int) -> list:
        """Membership signals due by ``step``: ``(kind, scenario)`` pairs.

        Kinds are ``"preempt_warn"`` / ``"host_lost"`` (from
        :class:`SpotPreemption`) and ``"join"`` (from :class:`JoinHost`).
        Each signal fires **exactly once** — ``>=`` comparisons mean a
        signal whose step fell inside a rebalance window still delivers
        at the next polled step.  The caller grounds signals against its
        live topology (a host shed before its deadline never *acts on*
        ``host_lost``; the one-shot here still consumes it).
        """
        out = []
        for s in self.scenarios:
            if isinstance(s, SpotPreemption):
                if step >= s.warn_step \
                        and ("warn", id(s)) not in self._membership_fired:
                    self._membership_fired.add(("warn", id(s)))
                    out.append(("preempt_warn", s))
                if step >= s.warn_step + s.deadline_steps \
                        and ("lost", id(s)) not in self._membership_fired:
                    self._membership_fired.add(("lost", id(s)))
                    out.append(("host_lost", s))
            elif isinstance(s, JoinHost):
                if step >= s.step \
                        and ("join", id(s)) not in self._membership_fired:
                    self._membership_fired.add(("join", id(s)))
                    out.append(("join", s))
        return out

    # --- preemption ---
    def maybe_preempt(self, step: int) -> None:
        """Deliver SIGTERM to ourselves once per Preemption scenario."""
        for s in self.scenarios:
            if isinstance(s, Preemption) and s.step == step \
                    and id(s) not in self._preempted:
                self._preempted.add(id(s))
                os.kill(os.getpid(), signal.SIGTERM)


@dataclasses.dataclass
class SimClock:
    """Accumulates simulated wall-clock: a synchronous step takes as long
    as its slowest participating host."""
    t: float = 0.0
    steps: int = 0

    def advance(self, host_times: dict) -> float:
        dt = max(host_times.values())
        self.t += dt
        self.steps += 1
        return dt

    def charge(self, seconds: float) -> None:
        """Account non-step downtime (checkpoint restore, re-compile)."""
        self.t += seconds

"""Fault tolerance: auto-resume, signal-triggered checkpoint, bounded retry.

The training driver (``launch/train.py``) wraps its step loop in
:class:`FaultTolerantLoop`:

- **auto-resume** — on start, the latest *committed* checkpoint (model +
  optimizer + data-pipeline state) is restored; a preempted/failed job
  relaunched by the cluster scheduler continues where it left off.
- **SIGTERM flush** — preemption notices trigger a final synchronous
  checkpoint before exit (TPU pods surface maintenance events as SIGTERM).
- **bounded retry** — transient step failures (in production: DCN flakes,
  preempted reductions) retry the step up to ``max_retries`` times from the
  last good in-memory state; persistent failure re-raises after a final
  checkpoint so the scheduler can reschedule, possibly at a different scale
  (see :mod:`repro.runtime.elastic`).
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import Any, Callable

from repro.ckpt.checkpoint import CheckpointManager


@dataclasses.dataclass
class FaultTolerantLoop:
    ckpt: CheckpointManager
    save_every: int = 100
    max_retries: int = 3
    async_save: bool = True

    def __post_init__(self):
        self._term_requested = False
        self._stop_requested = False
        self._abort_requested = False
        self._prev_handlers = {}

    # --- signal handling ---
    def _on_term(self, signum, frame):
        self._term_requested = True

    @property
    def preempted(self) -> bool:
        """True once a SIGTERM/SIGINT has been observed."""
        return self._term_requested

    # --- cooperative stop (elastic re-plan) ---
    def request_stop(self) -> None:
        """Ask the loop to exit after the current step with a final
        synchronous checkpoint — the controller's straggler-eviction hook
        (``on_step`` calls this; the loop returns and the caller re-plans
        and calls :meth:`run` again with the new state)."""
        self._stop_requested = True

    def request_abort(self) -> None:
        """Ask the loop to exit after the current step WITHOUT a final
        checkpoint — the deadline-missed membership path (a host died
        mid-segment, so the in-flight state must not be committed; the
        caller restores the last *committed* checkpoint and replays the
        lost steps exactly-once)."""
        self._abort_requested = True

    @property
    def aborted(self) -> bool:
        """True once :meth:`request_abort` ended the last :meth:`run`."""
        return self._abort_requested

    def install_signal_handlers(self) -> None:
        for sig in (signal.SIGTERM, signal.SIGINT):
            self._prev_handlers[sig] = signal.signal(sig, self._on_term)

    def restore_signal_handlers(self) -> None:
        for sig, h in self._prev_handlers.items():
            signal.signal(sig, h)

    # --- the loop ---
    def run(self, *, state: Any, step_fn: Callable, n_steps: int,
            start_step: int = 0, extra_fn: Callable | None = None,
            on_step: Callable | None = None) -> tuple:
        """Run ``state = step_fn(step, state)`` for steps [start, n_steps).

        ``extra_fn(state) -> dict`` supplies non-array state (data pipeline
        position etc.) for each checkpoint; an ``extra_fn(state, step)``
        two-argument form also receives the step being committed — the
        retry-exhausted final save commits at the *failed* step, and a
        data pipeline that already consumed that step's batch must report
        the position of the committed step, not its cursor (exactly-once).
        Returns (final_step, state).
        """
        self.install_signal_handlers()
        self._stop_requested = False
        self._abort_requested = False
        step = start_step
        try:
            while step < n_steps:
                retries = 0
                while True:
                    try:
                        t0 = time.monotonic()
                        state = step_fn(step, state)
                        dt = time.monotonic() - t0
                        break
                    except (RuntimeError, ValueError):
                        retries += 1
                        if retries > self.max_retries:
                            self._final_save(step, state, extra_fn)
                            raise
                if on_step is not None:
                    on_step(step, state, dt)
                step += 1
                if self._abort_requested:
                    break           # untrusted state: commit NOTHING
                if step % self.save_every == 0:
                    self._save(step, state, extra_fn)
                if self._term_requested or self._stop_requested:
                    self._final_save(step, state, extra_fn)
                    break
            else:
                self._final_save(step, state, extra_fn)
        finally:
            self.ckpt.wait()
            self.restore_signal_handlers()
        return step, state

    @staticmethod
    def _extra(step, state, extra_fn) -> dict:
        if extra_fn is None:
            return {}
        import inspect
        try:
            params = inspect.signature(extra_fn).parameters.values()
            # two-arg form = a second REQUIRED positional parameter; a
            # defaulted second parameter (extra_fn=lambda st, verbose=False)
            # keeps the documented one-arg contract and must not have the
            # step misbound into it
            required = [p for p in params
                        if p.kind in (p.POSITIONAL_ONLY,
                                      p.POSITIONAL_OR_KEYWORD)
                        and p.default is p.empty]
            two_arg = len(required) >= 2
        except (TypeError, ValueError):
            two_arg = False
        return extra_fn(state, step) if two_arg else extra_fn(state)

    def _save(self, step, state, extra_fn):
        extra = self._extra(step, state, extra_fn)
        if self.async_save:
            self.ckpt.save_async(step, state, extra=extra)
        else:
            self.ckpt.save(step, state, extra=extra)

    def _final_save(self, step, state, extra_fn):
        self.ckpt.wait()
        self.ckpt.save(step, state, extra=self._extra(step, state, extra_fn))

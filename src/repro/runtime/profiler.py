"""Profiling mode: timing observations → calibrated ``Hardware`` tables.

The recording half of the sim-to-measured loop (DESIGN.md §10;
:mod:`repro.core.calibrate` is the fitting half).  A :class:`Profiler`
accumulates per-device-group :class:`~repro.core.calibrate.Observation`\\ s
from whatever timing source is available:

- whole training steps (``record_step``) with the feature vector from
  ``cost_model.step_cost_features`` — what :class:`TrainController` feeds it
  each step, timed on real devices or on the fault injector's simulated
  clock in tests;
- individual collectives (``record_collective``), converted to
  ring-*effective* byte volumes with the same formulas the cost model
  prices, so the fitted bandwidth is directly the table entry;
- HBM-bound kernels (``record_kernel``) by traffic bytes;
- whole compiled modules (``record_hlo``) with byte volumes extracted by
  ``launch/hlo_analysis.py``'s ``collective_bytes``/``hbm_traffic_bytes``.

Observations are windowed per group (``max_per_group`` keeps memory bounded
and lets drifting hardware age out of the fit) and turned into
:class:`~repro.core.calibrate.CalibratedHardware` via ``fit_group`` /
``fit_spec``.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping

from repro.core.calibrate import (CalibratedHardware, Observation, fit,
                                  prediction_error, refit_spec)
from repro.core.cost_model import (ClusterSpec, Hardware, all_gather_time,
                                   all_reduce_time, all_to_all_time,
                                   hardware_reciprocals, p2p_time)

__all__ = ["Profiler", "ring_effective_bytes"]


# Ring-effective byte volume per collective kind at unit bandwidth — the
# same formulas step_cost prices with, so fitted bandwidth == table entry.
_RING = {
    "all-reduce": lambda b, n: all_reduce_time(b, n, 1.0),
    "all-gather": lambda b, n: all_gather_time(b, n, 1.0),
    "reduce-scatter": lambda b, n: all_gather_time(b, n, 1.0),
    "all-to-all": lambda b, n: all_to_all_time(b, n, 1.0),
    "collective-permute": lambda b, n: p2p_time(b, 1.0),
    "p2p": lambda b, n: p2p_time(b, 1.0),
}


def ring_effective_bytes(kind: str, payload_bytes: float, n: int) -> float:
    """Bytes actually moved per link by one ``kind`` over ``n`` ranks."""
    try:
        return _RING[kind](float(payload_bytes), int(n))
    except KeyError:
        raise ValueError(
            f"unknown collective kind {kind!r}; expected one of "
            f"{sorted(_RING)}") from None


@dataclasses.dataclass
class Profiler:
    """Accumulates timing observations per device group and fits tables.

    ``max_per_group`` bounds each group's buffer; recording past it drops
    the oldest observations, so long-running jobs fit over a sliding
    window and hardware drift ages out instead of being averaged away.
    """
    max_per_group: int = 4096

    def __post_init__(self) -> None:
        self._obs: dict[str, list[Observation]] = {}

    # -- recording ----------------------------------------------------------

    def record(self, obs: Observation) -> None:
        buf = self._obs.setdefault(obs.group, [])
        buf.append(obs)
        if len(buf) > self.max_per_group:
            del buf[: len(buf) - self.max_per_group]

    def record_step(self, group: str, wall_s: float,
                    features: Mapping[str, float], *, step: int = -1) -> None:
        """One whole training step: ``features`` from step_cost_features."""
        if wall_s > 0.0:
            self.record(Observation("step", group, float(wall_s),
                                    dict(features), step))

    def record_compute(self, group: str, wall_s: float, flops: float, *,
                       step: int = -1) -> None:
        """A pure-compute interval (matmul-dominated, no collectives)."""
        if wall_s > 0.0 and flops > 0.0:
            self.record(Observation("compute", group, float(wall_s),
                                    {"eff_flops": float(flops)}, step))

    def record_collective(self, group: str, kind: str, payload_bytes: float,
                          n: int, wall_s: float, *, link: str = "fast",
                          step: int = -1) -> None:
        """One timed collective over ``n`` ranks on the given link kind."""
        eff = ring_effective_bytes(kind, payload_bytes, n)
        if wall_s > 0.0 and eff > 0.0:
            self.record(Observation("collective", group, float(wall_s),
                                    {"link_" + link: eff}, step))

    def record_kernel(self, group: str, hbm_bytes: float, wall_s: float, *,
                      step: int = -1) -> None:
        """An HBM-bound kernel by its traffic bytes (e.g. from
        ``hlo_analysis.hbm_traffic_bytes`` on the kernel's module)."""
        if wall_s > 0.0 and hbm_bytes > 0.0:
            self.record(Observation("kernel", group, float(wall_s),
                                    {"hbm_bw": float(hbm_bytes)}, step))

    def record_hlo(self, group: str, hlo_text: str, n_devices: int,
                   wall_s: float, *, link: str = "fast", flops: float = 0.0,
                   step: int = -1) -> None:
        """One execution of a compiled module, features from its HLO.

        Collective traffic comes from ``collective_bytes`` (already
        ring-effective and trip-count-weighted), HBM traffic from
        ``hbm_traffic_bytes``; pass the module's known FLOP count to also
        constrain ``eff_flops``.
        """
        from repro.launch.hlo_analysis import (collective_bytes,
                                               hbm_traffic_bytes)
        feats: dict[str, float] = {}
        coll = collective_bytes(hlo_text, n_devices)
        if coll.get("total", 0.0) > 0.0:
            feats["link_" + link] = float(coll["total"])
        hbm = float(hbm_traffic_bytes(hlo_text))
        if hbm > 0.0:
            feats["hbm_bw"] = hbm
        if flops > 0.0:
            feats["eff_flops"] = float(flops)
        if feats and wall_s > 0.0:
            self.record(Observation("step", group, float(wall_s), feats,
                                    step))

    # -- inspection ---------------------------------------------------------

    @property
    def groups(self) -> tuple[str, ...]:
        return tuple(self._obs)

    def n_obs(self, group: str | None = None) -> int:
        if group is not None:
            return len(self._obs.get(group, ()))
        return sum(len(v) for v in self._obs.values())

    def window(self, group: str,
               last_n: int | None = None) -> list[Observation]:
        buf = self._obs.get(group, [])
        return list(buf if last_n is None else buf[-last_n:])

    def clear(self, group: str | None = None) -> None:
        if group is None:
            self._obs.clear()
        else:
            self._obs.pop(group, None)

    # -- fitting ------------------------------------------------------------

    def fit_group(self, group: str, base: Hardware, *,
                  last_n: int | None = None, **kw) -> CalibratedHardware:
        """Fit ``base`` from this group's (windowed) observations."""
        return fit(self.window(group, last_n), base, **kw)

    def fit_spec(self, spec: ClusterSpec, *, last_n: int | None = None,
                 **kw) -> tuple[ClusterSpec, dict[str, CalibratedHardware]]:
        """Re-fit every group of ``spec`` that has observations.

        Returns the calibrated spec plus the per-group fits (for event
        logs / ``rebalance(hardware=...)``).  Groups without observations
        keep their prior table.
        """
        fits = {g.name: self.fit_group(g.name, g.hw, last_n=last_n, **kw)
                for g in spec.groups if self.n_obs(g.name)}
        return refit_spec(spec, fits), fits

    def error(self, group: str, hw: Hardware, *,
              last_n: int | None = None) -> float:
        """Mean relative predicted-vs-measured error on the window."""
        return prediction_error(self.window(group, last_n), hw)

    def report(self, spec: ClusterSpec, *,
               last_n: int | None = None) -> str:
        """Human-readable calibration table (``launch/train.py --profile``)."""
        lines = ["calibration report (fitted vs prior; confidence in [0,1])"]
        for g in spec.groups:
            n = self.n_obs(g.name)
            if not n:
                lines.append(f"  {g.name}: no observations")
                continue
            fitted = self.fit_group(g.name, g.hw, last_n=last_n)
            prior_r = hardware_reciprocals(g.hw)
            fit_r = hardware_reciprocals(fitted)
            err = self.error(g.name, fitted, last_n=last_n)
            lines.append(f"  {g.name}: n={n} pred_err={err:.3f}")
            for p in sorted(fit_r):
                rate_f, rate_p = 1.0 / fit_r[p], 1.0 / prior_r[p]
                conf = fitted.confidence.get(p, 0.0)
                lines.append(
                    f"    {p:<10} {rate_f:>12.4g}  (prior {rate_p:>12.4g}, "
                    f"x{rate_f / rate_p:5.2f}, conf {conf:.2f})")
        return "\n".join(lines)

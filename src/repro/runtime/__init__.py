"""Runtime services: fault tolerance, straggler mitigation, elastic scaling,
fault injection (the self-healing loop of DESIGN.md §7)."""
from repro.runtime.elastic import (ElasticContext, HostTopology,  # noqa: F401
                                   SimHost, shrink_devices)
from repro.runtime.fault_tolerance import FaultTolerantLoop  # noqa: F401
from repro.runtime.faults import (CrashStep, FaultInjector,  # noqa: F401
                                  Preemption, SimClock, SlowHost)
from repro.runtime.straggler import (HostStragglerAggregator,  # noqa: F401
                                     StragglerMonitor)

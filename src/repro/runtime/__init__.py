"""Runtime services: fault tolerance, straggler mitigation, elastic scaling,
fault injection, and the event-driven cluster-membership controller (the
self-healing loop of DESIGN.md §7 and the membership runtime of §12)."""
from repro.runtime.elastic import (ElasticContext, HostTopology,  # noqa: F401
                                   SimHost, grow_devices, shrink_devices)
from repro.runtime.fault_tolerance import FaultTolerantLoop  # noqa: F401
from repro.runtime.faults import (CrashStep, DriftHost,  # noqa: F401
                                  FaultInjector, JoinHost, Preemption,
                                  SimClock, SlowHost, SpotPreemption)
from repro.runtime.straggler import (HostStragglerAggregator,  # noqa: F401
                                     StragglerMonitor)
# controller imports the siblings above, so it goes last (no cycle: none of
# elastic/faults/straggler import it back)
from repro.runtime.controller import (CalibrationConfig,  # noqa: F401
                                      ClusterController, ClusterEvent,
                                      DriftSource, DriftSustained,
                                      ElasticConfig, HostJoin, HostLost,
                                      IllegalTransition, InjectorSource,
                                      MembershipChange,
                                      MembershipStateMachine,
                                      PreemptionWarning, StragglerSource,
                                      StragglerSustained)

"""Runtime services: fault tolerance, straggler mitigation, elastic scaling."""
from repro.runtime.elastic import ElasticContext, shrink_devices  # noqa: F401
from repro.runtime.fault_tolerance import FaultTolerantLoop  # noqa: F401
from repro.runtime.straggler import (HostStragglerAggregator,  # noqa: F401
                                     StragglerMonitor)
